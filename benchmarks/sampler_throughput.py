"""Sampler throughput: sequential oracle vs TPU-native chunked vs kernel path.

The paper's own evaluation skips runtime ("similar to widely applied distinct
counting algorithms"); for a framework the element-rate IS the product, so we
measure it: elements/second for the oracle (Algorithm 5), the vectorized
fixed-k sampler at several chunk sizes, the capscore elementwise stage alone,
and — the headline — the multi-lane ``update_multi`` ingest across its three
generations:

* ``reference``: the pre-single-sort path (PR 4's oracle, verbatim in src);
* ``sorted``: the single-sort path exactly as it shipped before the fused
  restructure — frozen HERE (legacy primitive forms included) so the
  trajectory point stays measurable after src moved on;
* ``fused``: the current permute-once / score-ordered / reduce-fused path.

Per-stage timings are **jitted** closures timed by **min-of-rounds**
(matching query_throughput.py) — the previous single-shot wall times mostly
measured eager dispatch overhead and machine noise, which is how a ~0.2ms
fused score+aggregate stage was once booked at 17ms.

    PYTHONPATH=src python -m benchmarks.sampler_throughput \
        [--smoke] [--json PATH] [--backend {auto,cpu,gpu,tpu,interpret}] \
        [--check-stamps COMMITTED.json]

``--backend`` pins the kernel routes for the whole run (the CI matrix axis):
``auto`` keeps per-platform dispatch, ``cpu`` forces the XLA routes,
``interpret`` forces the Pallas routes in interpret mode (tile configs
exercised, nothing compiled), ``gpu``/``tpu`` force the compiled Pallas
routes and SKIP with a reason when the host platform does not match (exit 0
— a skipped leg is not a failed leg).

``--json`` emits a machine-readable record (schema_version 4: stamped with
the backend axis and a per-kernel ``{name, backend, compiled, tile_config}``
list — replacing v3's single global ``capscore_interpret`` flag — plus the
reprolint version/retrace budgets the timings were taken under).
``--smoke`` additionally acts as the CI perf-regression gate: the job FAILS
if the fused path measures slower than the reference oracle (per leg, both
paths scored through the leg's kernel route).  ``--check-stamps`` compares
the emitted kernel stamps against a committed record (both normalized
through the v3/v4 reader) and fails on drift.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import incremental as I
from repro.core import samplers as S
from repro.core import vectorized as V
from repro.core.segments import (
    EMPTY, ChunkOrder, chunk_order, scatter_unique, segment_ids,
)
from repro.kernels.capscore.capscore import _INTERPRET_ENV, default_interpret
from repro.kernels.capscore.ops import capscore, capscore_agg, capscore_multi
from repro.kernels.capscore.tiling import resolve_backend, tile_config
from repro.kernels.chunksort import sort_with_perm as chunksort_with_perm

SCHEMA_VERSION = 4

#: kernel entry points stamped into schema-v4 records
KERNEL_NAMES = ("capscore", "capscore_multi", "capscore_agg", "chunksort")

BACKEND_AXES = ("auto", "cpu", "gpu", "tpu", "interpret")


def resolve_backend_axis(axis: str):
    """Map a --backend axis value onto (kernel_backend, skip_reason).

    ``kernel_backend`` is the dispatch route handed to SamplerSpec.backend /
    the kernel ops: None (auto), 'xla', or 'pallas'.  A non-None
    ``skip_reason`` means this leg cannot run on the current host (compiled
    legs on a CPU runner) and the caller should exit 0 without timing.

    The interpret leg sets ``REPRO_CAPSCORE_INTERPRET=1`` — the authoritative
    env override, read at trace time — so every Pallas route runs the real
    tile configs through the interpreter.
    """
    plat = jax.default_backend()
    if axis == "auto":
        return None, None
    if axis == "cpu":
        if plat != "cpu":
            return None, f"cpu (XLA-route) leg requested on a {plat} host"
        return "xla", None
    if axis == "interpret":
        os.environ[_INTERPRET_ENV] = "1"
        return "pallas", None
    if axis in ("gpu", "tpu"):
        if plat != axis:
            return None, (f"{axis} leg needs a {axis} host to compile its "
                          f"Pallas route (found {plat!r})")
        return "pallas", None
    raise ValueError(f"unknown --backend axis {axis!r}: use one of {BACKEND_AXES}")


def kernel_stamps(kernel_backend: str | None = None):
    """Schema-v4 per-kernel stamps: dispatch route, compiled?, tile config.

    Deterministic given (host platform, backend axis, interpret env) — the
    CI interpret leg diffs these against the committed snapshot."""
    route = resolve_backend(kernel_backend)
    interp = bool(default_interpret())
    out = []
    for name in KERNEL_NAMES:
        if route == "pallas":
            cfg = tile_config(name)
            out.append({"name": name, "backend": "pallas",
                        "compiled": bool(cfg.compiled and not interp),
                        "tile_config": cfg.describe()})
        else:
            out.append({"name": name, "backend": "xla", "compiled": False,
                        "tile_config": None})
    return out


def kernel_stamps_from_record(record: dict):
    """Normalize a BENCH_ingest record's kernel stamps across schemas.

    v4 records carry the per-kernel list verbatim; v3 records carried one
    global ``capscore_interpret`` flag and predate the chunksort kernel, so
    they normalize to the equivalent per-kernel entries (no tile configs).
    Keeping this reader v3-capable is what lets benchmarks/run.py and
    --check-stamps consume historical records unchanged."""
    if int(record.get("schema_version", 0)) >= 4:
        return record["kernels"]
    interp = bool(record.get("capscore_interpret", True))
    plat = record.get("backend", "cpu")
    route = "pallas" if plat == "tpu" else "xla"
    compiled = route == "pallas" and not interp
    return [{"name": n, "backend": route, "compiled": compiled,
             "tile_config": None}
            for n in ("capscore", "capscore_multi", "capscore_agg")]


def reprolint_stamp():
    """Compile-count context for the perf numbers (DESIGN.md §11.3): the
    reprolint version and the committed retrace budgets these timings were
    taken under. Best-effort — absent files just leave the stamp empty."""
    root = Path(__file__).resolve().parents[1]
    stamp: dict = {}
    try:
        m = re.search(r'__version__\s*=\s*"([^"]+)"',
                      (root / "tools/reprolint/__init__.py").read_text())
        if m:
            stamp["reprolint_version"] = m.group(1)
        stamp["retrace_budgets"] = json.loads(
            (root / "tools/reprolint/reprolint_traces.json").read_text()
        )["budgets"]
    except (OSError, KeyError, ValueError):
        pass
    return stamp


def bench(fn, *args, reps=3, **kw):
    """Min-of-rounds timing: the machine-capability number on shared boxes
    (a single-shot wall time is dominated by whoever else runs that second).
    """
    out = fn(*args, **kw)  # warm/compile
    jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best


def _zipf(n, n_keys=50000, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.zipf(1.3, size=n) % n_keys).astype(np.int64)


# ---------------------------------------------------------------------------
# The pre-fuse single-sort ingest step, FROZEN (the PR's "before" point).
#
# src keeps only the pre-single-sort reference as a living oracle; the
# single-sort generation is reconstructed here verbatim — including the
# primitive forms it ran on (scatter-form unique keys, iota-query
# searchsorted compaction, full-width run interleave, top_k eviction
# threshold), all of which the fused restructure replaced — so ``sorted_eps``
# keeps measuring the same computation across PRs.
# ---------------------------------------------------------------------------

_INF = jnp.float32(jnp.inf)


def _legacy_chunk_order(keys):
    perm = jnp.argsort(keys, stable=True)
    ks = keys[perm]
    seg, _ = segment_ids(ks)
    ukeys, _ = scatter_unique(ks, seg, 0.0)
    return ChunkOrder(ks=ks, perm=perm, seg=seg, ukeys=ukeys)


def _legacy_compact_valid(valid, *arrays, fills):
    n = valid.shape[0]
    cs = jnp.cumsum(valid)
    src = jnp.clip(jnp.searchsorted(cs, jnp.arange(1, n + 1), side="left"),
                   0, n - 1)
    keep = jnp.arange(n) < cs[-1]
    return tuple(jnp.where(keep, a[src], jnp.asarray(fill, dtype=a.dtype))
                 for a, fill in zip(arrays, fills))


def _legacy_merge_sorted_runs_gather(a, b):
    na, nb = a.shape[0], b.shape[0]
    pos_b = jnp.arange(nb) + jnp.searchsorted(a, b, side="right")
    p = jnp.arange(na + nb)
    nb_before = jnp.searchsorted(pos_b, p, side="right")
    ib = jnp.clip(nb_before - 1, 0, nb - 1)
    from_b = (nb_before > 0) & (pos_b[ib] == p)
    ia = jnp.clip(p - nb_before, 0, na - 1)
    return from_b, ia, ib


def _legacy_merge_table_sorted(state, agg):
    cap = state.keys.shape[0]
    C = agg.ukeys.shape[0]
    a_keys, b_keys = state.keys, agg.ukeys
    a_live = a_keys != EMPTY
    b_live = b_keys != EMPTY
    loc_ab = jnp.clip(jnp.searchsorted(b_keys, a_keys), 0, C - 1)
    hit_a = (b_keys[loc_ab] == a_keys) & a_live
    counts_a = state.counts + jnp.where(hit_a, agg.w_total[loc_ab], 0.0)
    kb_a = jnp.minimum(state.kb, jnp.where(hit_a, agg.kb[loc_ab], _INF))
    sd_a = jnp.minimum(state.seed, jnp.where(hit_a, agg.min_score[loc_ab], _INF))
    loc_ba = jnp.clip(jnp.searchsorted(a_keys, b_keys), 0, cap - 1)
    in_table = a_keys[loc_ba] == b_keys
    new = b_live & ~in_table & agg.entered
    newk, newcnt, newkb, newsd = _legacy_compact_valid(
        new, b_keys, agg.contrib, agg.kb, agg.min_score,
        fills=(EMPTY, 0.0, _INF, _INF))
    from_b, ia, ib = _legacy_merge_sorted_runs_gather(a_keys, newk)
    pick = lambda av, bv: jnp.where(from_b, bv[ib], av[ia])
    return (pick(a_keys, newk)[:cap], pick(counts_a, newcnt)[:cap],
            pick(kb_a, newkb)[:cap], pick(sd_a, newsd)[:cap])


def _legacy_evict_table(table, *, k, l, salt, max_evict):
    valid, z, entry_thresh, ex, inv_l = V._evict_z(
        table.keys, table.counts, table.kb, table.tau, l, salt, table.step)
    n = table.keys.shape[0]
    delta = jnp.maximum(jnp.sum(valid.astype(jnp.int32)) - k, 0)
    z_top = jax.lax.top_k(z, min(int(max_evict), n))[0]
    tau_star = jnp.where(delta > 0, z_top[jnp.maximum(delta - 1, 0)], table.tau)
    keys_e, counts_e, kb_e, seed_e, tau_e = V._evict_apply(
        table.keys, table.counts, table.kb, table.seed, table.tau, l, delta,
        tau_star, valid, z, entry_thresh, ex, inv_l)
    keys_c, counts_c, kb_c, seed_c = _legacy_compact_valid(
        keys_e != EMPTY, keys_e, counts_e, kb_e, seed_e,
        fills=(EMPTY, 0.0, _INF, _INF))
    return V.TableState(keys_c, counts_c, kb_c, seed_c, tau_e, table.step,
                        table.overflow)


def _update_multi_sorted_impl(state, keys, weights, spec):
    """The single-sort multi-l batch update, as shipped pre-fuse."""
    chunk = spec.chunk
    kc = keys.reshape(-1, chunk)
    wc = weights.reshape(-1, chunk)
    cap_bk = state.bk_keys.shape[1]

    def body(carry, xs):
        table, bk_keys, bk_seeds, pos = carry
        ck, cw = xs
        eids = spec.eids(pos)
        score, delta, entry, kb = capscore_multi(ck, eids, cw, state.l,
                                                 table.tau, state.salt)
        order = _legacy_chunk_order(ck)

        def lane_merge(tab, sc, dl, en, kb_l):
            agg = V.aggregate_continuous_scored(ck, cw, sc, dl, en, kb_l, order)
            keys_c, counts_c, kb_c, seed_c = _legacy_merge_table_sorted(tab, agg)
            return V.TableState(keys_c, counts_c, kb_c, seed_c, tab.tau,
                                tab.step + 1, tab.overflow)

        table = jax.vmap(lane_merge)(table, score, delta, entry, kb)
        table = jax.vmap(
            lambda tab, l: _legacy_evict_table(tab, k=spec.k, l=l,
                                               salt=state.salt, max_evict=chunk)
        )(table, state.l)
        bk_keys, bk_seeds = V.pass1_step_multi(
            (bk_keys, bk_seeds), ck, score, cap=cap_bk, order=order)
        return (table, bk_keys, bk_seeds, pos + chunk), None

    (table, bkk, bks, pos), _ = jax.lax.scan(
        body, (state.table, state.bk_keys, state.bk_seeds, state.n_seen),
        (kc, wc))
    return I.SamplerState(table, pos, state.l, state.salt, bkk, bks)


# reprolint: disable=RPL003 -- bench harness: min-of-rounds timing re-feeds
# the same input state every round, so its buffers must stay alive
_update_multi_sorted = functools.partial(
    jax.jit, static_argnames=("spec",))(_update_multi_sorted_impl)


# ---------------------------------------------------------------------------
# Multi-lane ingest: fused vs pre-fuse single-sort vs pre-single-sort
# ---------------------------------------------------------------------------


def _stage_timings(L, k, chunk, reps=5, backend=None):
    """Min-of-rounds timings of each JITTED pipeline stage, fused vs legacy.

    Every stage is compiled before timing; what remains is the device compute
    the scan body actually pays.  The share of the chunk budget spent on
    score+aggregate is reported against one full fused chunk step.
    ``backend`` pins every kernel route (score, aggregate, chunk sort) to one
    leg of the CI matrix; None keeps per-platform dispatch.
    """
    ls = jnp.asarray(np.geomspace(1.0, 2.0 ** (L - 1), L), jnp.float32)
    ck = jnp.asarray(_zipf(chunk, seed=3)[:chunk], jnp.int32)
    cw = jnp.ones(chunk, jnp.float32)
    eids = jnp.arange(chunk, dtype=jnp.int32)
    salt = jnp.uint32(1)

    # a warmed, representative state: ingest a few chunks so tau is finite
    state, spec = I.init_multi_state(np.asarray(ls), k=k, chunk=chunk, salt=1,
                                     backend=backend)
    warm = _zipf(chunk * 4, seed=5).astype(np.int32)
    state = I.update_multi(state, warm, np.ones(len(warm), np.float32), spec,
                           donate=False)
    table = state.table
    cap_bk = state.bk_keys.shape[1]

    j_order = jax.jit(lambda c, e, w: chunk_order(c, e, w,
                                                  sort_backend=backend))
    order = j_order(ck, eids, cw)
    j_sort = jax.jit(lambda c: chunksort_with_perm(c, backend=backend))
    j_sort(ck)
    j_score = jax.jit(lambda: capscore_multi(ck, eids, cw, ls, table.tau, salt,
                                             backend=backend))
    score = j_score()[0]
    j_fused = jax.jit(lambda: capscore_agg(order.ks, order.eids, order.ws,
                                           order.seg, ls, table.tau, salt,
                                           backend=backend))
    cols = j_fused()

    def agg_shared():
        s, d, e, kb = capscore_multi(ck, eids, cw, ls, table.tau, salt,
                                     backend=backend)
        return jax.vmap(
            lambda s_, d_, e_, b_: V.aggregate_continuous_scored(
                ck, cw, s_, d_, e_, b_, order)
        )(s, d, e, kb)

    j_agg_shared = jax.jit(agg_shared)

    def lane_aggs():
        w_total, entered, contrib, kb_min, min_score = cols
        return jax.vmap(lambda en, ct, kbm, ms: V.ChunkAgg(
            ukeys=order.ukeys, w_total=w_total, entered=en, contrib=ct,
            kb=kbm, min_score=ms))(entered, contrib, kb_min, min_score)

    aggs = jax.jit(lane_aggs)()

    j_merge = jax.jit(lambda t, a: jax.vmap(V.fixed_k_merge)(t, a))
    merged = j_merge(table, aggs)
    j_evict_rank = jax.jit(lambda t: jax.vmap(
        lambda tt, l: V.evict_table(tt, k=k, l=l, salt=salt, max_evict=chunk,
                                    select="rank"))(t, ls))
    j_evict_topk = jax.jit(lambda t: jax.vmap(
        lambda tt, l: V.evict_table(tt, k=k, l=l, salt=salt, max_evict=chunk,
                                    select="topk"))(t, ls))

    bkk, bks = jax.vmap(V.summary_to_keysorted)(state.bk_keys, state.bk_seeds)
    j_pass1_fold = jax.jit(lambda b1, b2: jax.vmap(
        lambda sk, ss, mn: V.pass1_fold_keysorted(sk, ss, order.ukeys, mn, cap_bk)
    )(b1, b2, cols[4]))
    j_pass1_legacy = jax.jit(lambda b1, b2: V.pass1_step_multi(
        (b1, b2), ck, score, cap=cap_bk, order=order))

    # one whole fused chunk step — the budget the shares are measured against
    j_chunk = functools.partial(I.update_multi, donate=False)

    stages = {
        "order(1 sort + pre-gather)": lambda: j_order(ck, eids, cw),
        "sort-only[chunk-order route]": lambda: j_sort(ck),
        "score+aggregate[fused capscore_agg]": j_fused,
        "score+aggregate[legacy: score, gather x4L]": j_agg_shared,
        "merge[sorted-runs, L lanes]": lambda: j_merge(table, aggs),
        "evict[rank-select]": lambda: j_evict_rank(merged),
        "evict[legacy top_k]": lambda: j_evict_topk(merged),
        "pass1[key-sorted fold]": lambda: j_pass1_fold(bkk, bks),
        "pass1[legacy seed-sorted merge]": lambda: j_pass1_legacy(state.bk_keys, state.bk_seeds),
        "full chunk step[fused]": lambda: j_chunk(state, ck, cw, spec),
    }
    out = {name: bench(fn, reps=reps) * 1e3 for name, fn in stages.items()}
    chunk_ms = out["full chunk step[fused]"]
    out["score_agg_share_of_chunk"] = (
        out["score+aggregate[fused capscore_agg]"] / chunk_ms if chunk_ms else 0.0)
    return out


def multi_lane_ingest(L=8, k=4096, chunk=4096, n_chunks=4, reps=3, stage_reps=5,
                      backend=None):
    """Elements/s of the three ingest generations, min-of-rounds interleaved.

    ``backend`` pins both live paths (reference oracle and fused) to one
    kernel route so the perf gate compares like-for-like; the frozen
    pre-fuse ``sorted`` path keeps its shipped auto dispatch.
    """
    ls = np.geomspace(1.0, 2.0 ** (L - 1), L)
    n = n_chunks * chunk
    keys = _zipf(n, seed=11).astype(np.int32)
    w = np.ones(n, np.float32)

    state, spec = I.init_multi_state(ls, k=k, chunk=chunk, salt=2,
                                     backend=backend)
    # warm tau so steady-state (evicting) chunks are what gets timed
    state = I.update_multi(state, keys, w, spec, donate=False)
    kj, wj = jnp.asarray(keys), jnp.asarray(w)

    paths = {
        "reference": lambda: I.update_multi(state, keys, w, spec, donate=False,
                                            reference=True),
        "sorted": lambda: _update_multi_sorted(state, kj, wj, spec),
        "fused": lambda: I.update_multi(state, keys, w, spec, donate=False),
    }
    for fn in paths.values():  # compile before any timing
        fn()
    best = {name: float("inf") for name in paths}
    for _ in range(reps):  # interleave rounds so machine noise hits all paths
        for name, fn in paths.items():
            t0 = time.perf_counter()
            out = fn()
            jax.tree.map(lambda x: x.block_until_ready(), jax.tree.leaves(out))
            best[name] = min(best[name], time.perf_counter() - t0)

    stages = _stage_timings(L, k, chunk, reps=stage_reps, backend=backend)
    return {
        "L": L, "k": k, "chunk": chunk, "n": n,
        "reference_eps": n / best["reference"],
        "sorted_eps": n / best["sorted"],
        "fused_eps": n / best["fused"],
        "speedup_vs_reference": best["reference"] / best["fused"],
        "speedup_vs_sorted": best["sorted"] / best["fused"],
        "score_agg_share": stages["score_agg_share_of_chunk"],
        "stages_ms": stages,
    }


def print_ingest(res):
    print(f"\n-- multi-lane ingest (L={res['L']}, k={res['k']}, "
          f"chunk={res['chunk']}, n={res['n']}):")
    print(f"{'path':42s} {'elements/s':>14s}")
    print(f"{'update_multi[reference: pre-single-sort]':42s} {res['reference_eps']:14.0f}")
    print(f"{'update_multi[sorted: pre-fuse, frozen]':42s} {res['sorted_eps']:14.0f}")
    print(f"{'update_multi[fused score-in-key-order]':42s} {res['fused_eps']:14.0f}")
    print(f"speedup vs reference: {res['speedup_vs_reference']:.2f}x   "
          f"vs pre-fuse sorted: {res['speedup_vs_sorted']:.2f}x")
    print(f"\n{'per-stage (jitted, min-of-rounds)':42s} {'ms':>10s}")
    for name, ms in res["stages_ms"].items():
        if name == "score_agg_share_of_chunk":
            print(f"{'score+aggregate share of chunk step':42s} {ms:10.1%}")
        else:
            print(f"{name:42s} {ms:10.3f}")


def main(n=200_000, k=256, l=20.0, ingest_kw=None, json_path=None,
         perf_gate=False, backend_axis="auto", kernel_backend=None):
    rng = np.random.default_rng(0)
    keys = (rng.zipf(1.3, size=n) % 50000).astype(np.int64)
    rows = []

    t = bench(lambda: S.alg5_fixed_k_continuous(keys[:20000], None, k, l=l, salt=1), reps=1)
    rows.append(("alg5_sequential_oracle", 20000 / t, t * 1e6 / 20000))

    for chunk in (1024, 4096, 16384):
        t = bench(V.sample_fixed_k, keys, None, k=k, l=l, salt=1, chunk=chunk)
        rows.append((f"vectorized_fixed_k_chunk{chunk}", n / t, t * 1e6 / n))

    t = bench(V.sample_two_pass, keys, None, k=k, l=l, salt=1, chunk=4096)
    rows.append(("vectorized_two_pass", n / t, t * 1e6 / n))

    m = min(131072, n)
    kk = jnp.asarray(keys[:m], jnp.int32)
    ee = jnp.arange(m, dtype=jnp.int32)
    ww = jnp.ones(m, jnp.float32)
    j_cap = jax.jit(lambda: capscore(kk, ee, ww, l, 0.01, 3, backend="xla"))
    t = bench(j_cap)
    rows.append(("capscore_stage_xla", m / t, t * 1e6 / m))

    print(f"{'path':36s} {'elements/s':>14s} {'us/element':>12s}")
    for name, eps, us in rows:
        print(f"{name:36s} {eps:14.0f} {us:12.4f}")

    ingest = multi_lane_ingest(backend=kernel_backend, **(ingest_kw or {}))
    print_ingest(ingest)

    if json_path:
        record = {
            "bench": "sampler_throughput",
            "schema_version": SCHEMA_VERSION,
            "backend": jax.default_backend(),
            "backend_axis": backend_axis,
            "kernels": kernel_stamps(kernel_backend),
            "reprolint": reprolint_stamp(),
            "single_lane": {name: {"elements_per_s": eps} for name, eps, _ in rows},
            "multi_lane_ingest": {
                k_: v for k_, v in ingest.items() if k_ != "stages_ms"
            },
            "multi_lane_stages_ms": ingest["stages_ms"],
        }
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\n[sampler_throughput] wrote {json_path}")

    if perf_gate and ingest["speedup_vs_reference"] < 1.0:
        print(f"\nPERF REGRESSION: fused ingest measured "
              f"{ingest['speedup_vs_reference']:.2f}x the reference oracle "
              f"(must be >= 1.0x)", file=sys.stderr)
        sys.exit(1)
    return rows, ingest


def check_stamps(committed_path, kernel_backend):
    """Diff the committed record's kernel stamps against this host's.

    Both sides go through the v3/v4 reader so historical records still load;
    a mismatch (route drift, tile-config drift, stale snapshot) exits 1."""
    with open(committed_path) as f:
        committed = kernel_stamps_from_record(json.load(f))
    emitted = kernel_stamps(kernel_backend)
    if committed != emitted:
        print(f"\nKERNEL STAMP DRIFT vs {committed_path}:", file=sys.stderr)
        print(f"  committed: {json.dumps(committed)}", file=sys.stderr)
        print(f"  emitted:   {json.dumps(emitted)}", file=sys.stderr)
        print("  regenerate the snapshot with: python -m "
              "benchmarks.sampler_throughput --smoke --backend interpret",
              file=sys.stderr)
        sys.exit(1)
    print(f"[sampler_throughput] kernel stamps match {committed_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small L/k/chunk, emits JSON, enforces "
                         "the fused>=reference perf gate)")
    ap.add_argument("--json", default="BENCH_ingest.json",
                    help="machine-readable output path")
    ap.add_argument("--backend", default="auto", choices=BACKEND_AXES,
                    help="kernel-route leg: auto dispatch, forced xla (cpu), "
                         "forced Pallas interpret, or compiled gpu/tpu "
                         "(skips with a reason off-platform)")
    ap.add_argument("--check-stamps", default=None, metavar="PATH",
                    help="after the run, fail if PATH's kernel stamps differ "
                         "from this leg's")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    kernel_backend, skip = resolve_backend_axis(args.backend)
    if skip is not None:
        print(f"[sampler_throughput] SKIP --backend {args.backend}: {skip}")
        sys.exit(0)
    common = dict(json_path=args.json, backend_axis=args.backend,
                  kernel_backend=kernel_backend)
    if args.smoke:
        main(n=50_000, k=128,
             ingest_kw=dict(L=4, k=512, chunk=1024, n_chunks=2, reps=3,
                            stage_reps=2),
             perf_gate=True, **common)
    else:
        main(n=2_000_000 if args.full else 200_000,
             ingest_kw=dict(L=8, k=4096, chunk=4096), **common)
    if args.check_stamps:
        check_stamps(args.check_stamps, kernel_backend)
