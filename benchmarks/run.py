"""Benchmark entrypoint: one section per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run [--full]

Sections:
  1. paper-tables   — §7 l x T error grids (Figures 3/4 reduced-rep)
  2. cv-bounds      — empirical CV vs Thm 5.1/5.4 bounds across disparity
  3. multiobjective — Lemma 6.1 union sizes + combined-estimator accuracy
  4. throughput     — sampler elements/s (oracle vs vectorized vs kernel stage)
  5. service        — incremental StreamStatsService vs buffer-and-replay
  6. merge          — cross-host merge cost, exact vs approximate mode
  7. roofline       — summary of the dry-run roofline records (if present)
  8. query-plane    — batched query_batch vs per-query host estimation
  9. serving        — multi-tenant stacked bank + scheduler vs per-tenant loop
"""
from __future__ import annotations

import argparse
import math
import sys
import time
from pathlib import Path

import numpy as np


def section(title):
    print(f"\n{'='*74}\n== {title}\n{'='*74}")


def cv_bounds_bench(rep=60, k=150):
    from repro.core import continuous as C
    from repro.core import estimators as E
    from repro.core import freqfns as F
    from repro.core import vectorized as V

    rng = np.random.default_rng(11)
    keys = (rng.zipf(1.3, size=60000) % 20000).astype(np.int64)
    _, cnts = np.unique(keys, return_counts=True)
    print(f"{'l':>8} {'T':>7} {'empirical CV':>13} {'Thm5.4 bound':>13} ok")
    ok_all = True
    for l, T in [(20.0, 20), (20.0, 5), (20.0, 100), (5.0, 50), (100.0, 10)]:
        truth = F.exact_statistic(F.cap(T), cnts)
        es = [
            E.estimate(V.sample_fixed_k(keys, None, k=k, l=l, salt=900 + r), F.cap(T))
            for r in range(rep)
        ]
        cv = float(np.std(es) / truth)
        bound = C.cv_bound_one_pass(T, l, 1.0, k)
        ok = cv <= bound
        ok_all &= ok
        print(f"{l:>8g} {T:>7d} {cv:>13.4f} {bound:>13.4f} {'OK' if ok else 'VIOLATION'}")
    return ok_all


def multiobjective_bench():
    from repro.core import multiobjective as M

    rng = np.random.default_rng(5)
    keys = (rng.zipf(1.3, size=50000) % 20000).astype(np.int64)
    n = len(np.unique(keys))
    k = 64
    sizes = []
    for salt in range(6):
        uk, hx, y, _ = M.per_key_randomness(keys, None, salt=salt)
        sizes.append(len(M.union_sample_all_l(uk, hx, y, k)))
    bound = k * math.log(n)
    print(f"union |S_L| over L=(0,inf): mean {np.mean(sizes):.0f} "
          f"(k ln n bound = {bound:.0f}, k = {k}, n = {n})  "
          f"{'OK' if np.mean(sizes) <= bound else 'VIOLATION'}")
    return np.mean(sizes) <= bound


def roofline_summary():
    from benchmarks.roofline import load_records, roofline_terms

    recs_dir = "results/dryrun_opt" if Path("results/dryrun_opt").exists() else "results/dryrun"
    if not Path(recs_dir).exists():
        print("(no dry-run records; run repro.launch.dryrun first)")
        return True
    for mesh in ("pod1", "pod2"):
        rows = [roofline_terms(r) for r in load_records(recs_dir, mesh)]
        if not rows:
            continue
        rows.sort(key=lambda r: -r["roofline_fraction"])
        print(f"\n-- mesh {mesh} ({len(rows)} cells, from {recs_dir}) — top/bottom by roofline fraction:")
        for r in rows[:5] + rows[-3:]:
            print(f"  {r['cell']:44s} {r['dominant']:10s} roofline {r['roofline_fraction']:7.2%} "
                  f"peak {r['peak_gib']:6.1f} GiB")
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale reps (slow)")
    ap.add_argument("--skip-tables", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    ok = True

    if not args.skip_tables:
        section("1. Paper §7 tables: l x T error grids (reduced rep)")
        from benchmarks.paper_tables import main as tables_main

        res = tables_main(alphas=(1.2, 1.5), rep=(200 if args.full else 25), k=100,
                          full=args.full)
        ok &= all(v["diag"] and v["bound"] for v in res.values())

    section("2. CV bounds (Thm 5.4) across (l, T) disparity")
    ok &= cv_bounds_bench(rep=(200 if args.full else 40))

    section("3. Multi-objective samples (Lemma 6.1)")
    ok &= multiobjective_bench()

    section("4. Sampler throughput (+ multi-lane ingest -> BENCH_ingest.json)")
    from benchmarks.sampler_throughput import main as tp_main

    tp_main(n=200_000 if not args.full else 2_000_000,
            ingest_kw=(dict(L=8, k=4096, chunk=4096) if args.full
                       else dict(L=8, k=1024, chunk=2048, n_chunks=2)),
            json_path="BENCH_ingest.json")

    # schema-v3/v4 normalizing reader: historical records stay consumable
    from benchmarks.sampler_throughput import kernel_stamps_from_record
    import json as _json4

    with open("BENCH_ingest.json") as f:
        stamps = kernel_stamps_from_record(_json4.load(f))
    compiled = [s["name"] for s in stamps if s["compiled"]]
    print(f"\n[run] kernel routes: "
          + ", ".join(f"{s['name']}:{s['backend']}" for s in stamps)
          + f"  (compiled: {', '.join(compiled) if compiled else 'none'})")

    section("5. StreamStatsService: incremental vs buffer-and-replay")
    from benchmarks.service_throughput import main as svc_main

    svc_main(n=200_000 if not args.full else 2_000_000)

    section("6. Cross-host merge: exact vs approximate")
    from benchmarks.merge_throughput import main as merge_main

    merge_main(n=400_000 if not args.full else 4_000_000)

    section("7. Roofline summary (from dry-run records)")
    roofline_summary()

    section("8. Query plane: batched engine vs per-query host path")
    from benchmarks.query_throughput import main as query_main

    if args.full:
        query_main()
    else:
        query_main(n=100_000, k=1024, ls=(1.0, 8.0, 64.0),
                   batch_sizes=(1, 64), rounds=3, n_keys=50_000,
                   audience=10_000, check_target=False)

    section("9. Serving plane: stacked bank + scheduler vs per-tenant loop"
            " -> BENCH_serve.json")
    from benchmarks.sampler_throughput import reprolint_stamp
    from benchmarks.serve_throughput import SCHEMA_VERSION as SERVE_SCHEMA
    from benchmarks.serve_throughput import run as serve_run
    import json as _json

    serve_res = serve_run(**({} if args.full
                             else dict(rounds=6, chunk=256,
                                       queries_per_round=24, k=128)))
    ok &= serve_res["bit_identical"]
    with open("BENCH_serve.json", "w") as f:
        _json.dump({"bench": "serve_throughput", "schema_version": SERVE_SCHEMA,
                    "reprolint": reprolint_stamp(), **serve_res}, f, indent=2)

    print(f"\n[benchmarks] total {time.time()-t0:.0f}s — "
          f"{'ALL VALIDATIONS PASS' if ok else 'SOME VALIDATIONS FAILED'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
