"""Reproduction of the paper's §7 simulation tables.

Streams of m=1e5 uniform-weight elements, keys ~ Zipf(alpha); fixed-k
continuous + discrete SH_l samples for l in the paper's grid; estimates of
Q(cap_T, X) for T in the grid; relative error and NRMSE over `rep`
repetitions; 1-pass vs 2-pass.  Matches Figures 3/4's setup (rep scaled for
CPU wall-time; --full restores rep=200).

Validation criteria (asserted in benchmarks.run summary):
  * minimum error for each T is achieved at l within a factor ~4 of T
    (the paper's diagonal-dominance pattern);
  * NRMSE at l == T is within the Thm 5.4 bound.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import continuous as C
from repro.core import estimators as E
from repro.core import freqfns as F
from repro.core import samplers as S
from repro.core import vectorized as V

LS = (1.0, 5.0, 20.0, 50.0, 100.0, 1000.0, 10000.0)
TS = (1, 5, 20, 50, 100, 1000, 10000)


def run_grid(*, alpha: float, m: int = 100_000, k: int = 100, rep: int = 40,
             scheme: str = "continuous", seed0: int = 0, two_pass: bool = False):
    rng = np.random.default_rng(int(alpha * 1000) + 12345)  # deterministic
    # (python hash() is per-process randomized — not reproducible)
    keys = (rng.zipf(alpha, size=m) % (10**9)).astype(np.int64)
    # remap to compact ids so int32 tables stay small
    _, keys = np.unique(keys, return_inverse=True)
    ukeys, cnts = np.unique(keys, return_counts=True)
    truths = {T: F.exact_statistic(F.cap(T), cnts) for T in TS}

    relerr = {(l, T): [] for l in LS for T in TS}
    for r in range(rep):
        for l in LS:
            if two_pass:
                res = V.sample_two_pass(keys, None, k=k, l=l, kind=scheme, salt=seed0 + r)
            elif scheme == "continuous":
                res = V.sample_fixed_k(keys, None, k=k, l=l, salt=seed0 + r)
            else:
                res = S.alg3_fixed_k_discrete(keys, k, l=int(l), salt=seed0 + r)
            for T in TS:
                est = E.estimate(res, F.cap(T))
                relerr[(l, T)].append((est - truths[T]) / truths[T])

    table = {}
    for (l, T), errs in relerr.items():
        errs = np.asarray(errs)
        table[(l, T)] = {
            "relerr": float(np.mean(np.abs(errs))),
            "nrmse": float(np.sqrt(np.mean(errs**2))),
        }
    return table, truths, len(ukeys)


def format_table(table, metric="nrmse"):
    hdr = "l\\T   " + "".join(f"{T:>9}" for T in TS)
    lines = [hdr]
    for l in LS:
        row = [table[(l, T)][metric] for T in TS]
        best = [min(table[(l2, T)][metric] for l2 in LS) for T in TS]
        cells = "".join(
            f"{v:>8.3f}{'*' if v == b else ' '}" for v, b in zip(row, best)
        )
        lines.append(f"{l:<6g}{cells}")
    return "\n".join(lines)


def diagonal_dominance(table, metric="nrmse", slack=1.5) -> bool:
    """The paper's claim: the sample with l ~ T is near-optimal for cap_T.

    Criterion: for every T, the diagonal cell (closest l) is within `slack`
    of the column minimum.  (Testing the argmin position instead is noise-
    sensitive at reduced rep — neighboring cells differ by < the NRMSE
    estimator's own standard error, exactly as in the paper's Fig 3/4 where
    the starred minimum occasionally sits one step off the diagonal.)
    """
    ok = True
    for T in TS:
        best = min(table[(l, T)][metric] for l in LS)
        diag_l = min(LS, key=lambda l: max(l / T, T / l))
        ok &= table[(diag_l, T)][metric] <= slack * best + 1e-12
    return ok


def main(alphas=(1.2, 1.5), rep=40, k=100, full=False):
    if full:
        alphas, rep = (1.1, 1.2, 1.5, 1.8, 2.0), 200
    results = {}
    for alpha in alphas:
        for passes, twop in (("1-pass", False), ("2-pass", True)):
            t0 = time.time()
            table, truths, n_keys = run_grid(alpha=alpha, rep=rep, k=k, two_pass=twop)
            name = f"continuous k={k} alpha={alpha} rep={rep} {passes}"
            print(f"\n== {name}  (n_keys={n_keys}, {time.time()-t0:.0f}s) ==")
            print(format_table(table))
            diag = diagonal_dominance(table)
            bound_ok = all(
                table[(float(T), T)]["nrmse"]
                <= C.cv_bound_one_pass(T, T, 1.0, k) * 1.2
                for T in TS if float(T) in LS
            )
            print(f"diagonal-dominance: {diag}; CV bound at l=T: {bound_ok}")
            results[name] = {"diag": diag, "bound": bound_ok, "table": {str(k_): v for k_, v in table.items()}}
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rep", type=int, default=40)
    args = ap.parse_args()
    main(rep=args.rep, full=args.full)
