"""Roofline analysis from dry-run records (§Roofline deliverable).

Per (arch x shape x mesh):
    compute term    = corrected_FLOPs_per_device / peak_FLOPs
    memory term     = corrected_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Corrections (measured on this container, DESIGN.md §6):
  * cost_analysis() is PER-DEVICE;
  * scan bodies count ONCE -> add (L-1) x single-layer cost (the dry-run
    compiles the layer program separately and stores it under
    `layer_cost_per_device`), for FLOPs, bytes and collectives alike.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline --records results/dryrun \
        --mesh pod1 --markdown
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / link


def corrected_costs(rec: dict) -> dict:
    flops = rec["cost_per_device"]["flops"]
    bytes_ = rec["cost_per_device"]["bytes_accessed"]
    coll = {k: dict(v) for k, v in rec["collectives"].items()}
    lc = rec.get("layer_cost_per_device")
    if lc:
        m = lc["multiplier"]
        flops += m * lc["flops"]
        bytes_ += m * lc["bytes_accessed"]
        for k, v in lc["collectives"].items():
            coll.setdefault(k, {"count": 0, "bytes": 0})
            coll[k]["count"] += m * v["count"]
            coll[k]["bytes"] += m * v["bytes"]
    return {"flops": flops, "bytes": bytes_, "collectives": coll}


AR_TRAFFIC_FACTOR = 2.0  # ring all-reduce moves 2(P-1)/P ~ 2x its output bytes

# The CPU backend rewrites bf16 compute to f32 (verified: every collective
# and temp tensor in bf16 models lowers as f32, regardless of
# --xla_allow_excess_precision).  On TPU these run in bf16, so byte-counted
# terms for bf16 cells are ~2x pessimistic; we apply x0.5 to memory traffic,
# collective bytes and temp memory of bf16 cells and report it as the
# calibrated number (raw values stay in the JSON records).
BF16_CPU_INFLATION = 0.5


def roofline_terms(rec: dict) -> dict:
    c = corrected_costs(rec)
    dt_factor = BF16_CPU_INFLATION if rec.get("dtype") == "bfloat16" else 1.0
    coll_bytes = dt_factor * sum(
        v["bytes"] * (AR_TRAFFIC_FACTOR if k == "all-reduce" else 1.0)
        for k, v in c["collectives"].items()
    )
    t_compute = c["flops"] / PEAK_FLOPS
    t_memory = dt_factor * c["bytes"] / HBM_BW
    t_coll = coll_bytes / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    n_dev = 1
    for v in rec["mesh"].values():
        n_dev *= v
    model_flops_dev = rec["model_flops"] / n_dev
    bound = max(t_compute, t_memory, t_coll)
    return {
        "cell": rec["cell"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "useful_flops_ratio": model_flops_dev / max(c["flops"], 1.0),
        # fraction of roofline: useful work per achievable second vs peak
        "roofline_fraction": (model_flops_dev / PEAK_FLOPS) / max(bound, 1e-12),
        "peak_gib": (
            rec["bytes_per_device"]["peak_estimate"]
            - (1 - dt_factor) * rec["bytes_per_device"]["temps"]
        ) / 2**30,
        "coll_bytes_per_dev": coll_bytes,
        "flops_per_dev": c["flops"],
        "bytes_per_dev": c["bytes"],
    }


def load_records(records_dir: str, mesh_tag: str) -> list[dict]:
    out = []
    for fp in sorted(Path(records_dir).glob(f"*__{mesh_tag}.json")):
        out.append(json.loads(fp.read_text()))
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| cell | dominant | compute s | memory s | collective s | "
        "useful/HLO | roofline frac | peak GiB |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['dominant']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2%} | "
            f"{r['peak_gib']:.1f} |"
        )
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default="results/dryrun")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()

    recs = load_records(args.records, args.mesh)
    rows = [roofline_terms(r) for r in recs]
    rows.sort(key=lambda r: r["roofline_fraction"])
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in rows:
            print(
                f"{r['cell']:44s} {r['dominant']:10s} "
                f"comp {r['t_compute_s']:.2e} mem {r['t_memory_s']:.2e} "
                f"coll {r['t_collective_s']:.2e} useful {r['useful_flops_ratio']:.2f} "
                f"roofline {r['roofline_fraction']:.1%} peak {r['peak_gib']:.1f}GiB"
            )
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
