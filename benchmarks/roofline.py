"""Roofline analysis from dry-run records (§Roofline deliverable).

Per (arch x shape x mesh):
    compute term    = corrected_FLOPs_per_device / peak_FLOPs
    memory term     = corrected_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

Corrections (measured on this container, DESIGN.md §6):
  * cost_analysis() is PER-DEVICE;
  * scan bodies count ONCE -> add (L-1) x single-layer cost (the dry-run
    compiles the layer program separately and stores it under
    `layer_cost_per_device`), for FLOPs, bytes and collectives alike.

Hardware constants live in ``HW_TABLES`` — one entry per backend of the
kernel matrix (TPU v5e, A100, a reference CPU host); every term is computed
against a selected table, never a baked-in chip.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline --records results/dryrun \
        --mesh pod1 --markdown [--hw tpu-v5e]
    PYTHONPATH=src python -m benchmarks.roofline --chunk-step \
        [--chunk 4096] [--lanes 8] [--k 4096] [--hw gpu-a100]

``--chunk-step`` switches from dry-run records to the ANALYTIC ingest model:
bytes/FLOPs per element for each stage of one fused chunk step (sort, fused
score+aggregate, table merge, pass-1 fold), bounded against the selected
hardware table — the arithmetic-intensity map that says which stage hits the
memory wall first on each backend of the kernel matrix.
"""
from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

#: per-backend roofline constants for the kernel matrix (DESIGN.md §12.4):
#: peak_flops — dense peak per chip (bf16 on accelerators, f32 AVX on CPU);
#: hbm_bw — main-memory bandwidth per chip; link_bw — per-link interconnect
#: (ICI / NVLink / socket).  int32/f32 element width is 4 B on every backend.
HW_TABLES = {
    "tpu-v5e": {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9},
    "gpu-a100": {"peak_flops": 312e12, "hbm_bw": 1555e9, "link_bw": 300e9},
    "cpu-host": {"peak_flops": 2e12, "hbm_bw": 100e9, "link_bw": 25e9},
}
DEFAULT_HW = "tpu-v5e"

# legacy aliases (pre-table callers) — derived, not hardcoded
PEAK_FLOPS = HW_TABLES[DEFAULT_HW]["peak_flops"]
HBM_BW = HW_TABLES[DEFAULT_HW]["hbm_bw"]
LINK_BW = HW_TABLES[DEFAULT_HW]["link_bw"]


def _hw(hw) -> dict:
    """Resolve a hardware spec: None -> default table, str -> table lookup,
    dict -> verbatim (custom chips in tests)."""
    if hw is None:
        return HW_TABLES[DEFAULT_HW]
    if isinstance(hw, str):
        return HW_TABLES[hw]
    return hw


def corrected_costs(rec: dict) -> dict:
    flops = rec["cost_per_device"]["flops"]
    bytes_ = rec["cost_per_device"]["bytes_accessed"]
    coll = {k: dict(v) for k, v in rec["collectives"].items()}
    lc = rec.get("layer_cost_per_device")
    if lc:
        m = lc["multiplier"]
        flops += m * lc["flops"]
        bytes_ += m * lc["bytes_accessed"]
        for k, v in lc["collectives"].items():
            coll.setdefault(k, {"count": 0, "bytes": 0})
            coll[k]["count"] += m * v["count"]
            coll[k]["bytes"] += m * v["bytes"]
    return {"flops": flops, "bytes": bytes_, "collectives": coll}


AR_TRAFFIC_FACTOR = 2.0  # ring all-reduce moves 2(P-1)/P ~ 2x its output bytes

# The CPU backend rewrites bf16 compute to f32 (verified: every collective
# and temp tensor in bf16 models lowers as f32, regardless of
# --xla_allow_excess_precision).  On TPU these run in bf16, so byte-counted
# terms for bf16 cells are ~2x pessimistic; we apply x0.5 to memory traffic,
# collective bytes and temp memory of bf16 cells and report it as the
# calibrated number (raw values stay in the JSON records).
BF16_CPU_INFLATION = 0.5


def roofline_terms(rec: dict, hw=None) -> dict:
    """Roofline terms of one dry-run record against a hardware table.

    ``hw`` is a HW_TABLES key, a custom table dict, or None for the default
    (TPU v5e, the mesh the dry-run records model)."""
    t = _hw(hw)
    c = corrected_costs(rec)
    dt_factor = BF16_CPU_INFLATION if rec.get("dtype") == "bfloat16" else 1.0
    coll_bytes = dt_factor * sum(
        v["bytes"] * (AR_TRAFFIC_FACTOR if k == "all-reduce" else 1.0)
        for k, v in c["collectives"].items()
    )
    t_compute = c["flops"] / t["peak_flops"]
    t_memory = dt_factor * c["bytes"] / t["hbm_bw"]
    t_coll = coll_bytes / t["link_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    n_dev = 1
    for v in rec["mesh"].values():
        n_dev *= v
    model_flops_dev = rec["model_flops"] / n_dev
    bound = max(t_compute, t_memory, t_coll)
    return {
        "cell": rec["cell"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "useful_flops_ratio": model_flops_dev / max(c["flops"], 1.0),
        # fraction of roofline: useful work per achievable second vs peak
        "roofline_fraction": (model_flops_dev / t["peak_flops"]) / max(bound, 1e-12),
        "peak_gib": (
            rec["bytes_per_device"]["peak_estimate"]
            - (1 - dt_factor) * rec["bytes_per_device"]["temps"]
        ) / 2**30,
        "coll_bytes_per_dev": coll_bytes,
        "flops_per_dev": c["flops"],
        "bytes_per_dev": c["bytes"],
    }


# ---------------------------------------------------------------------------
# Analytic ingest chunk-step model (--chunk-step)
# ---------------------------------------------------------------------------

#: --hw key -> tiling flavor of the kernel matrix
_HW_FLAVOR = {"tpu-v5e": "tpu", "gpu-a100": "gpu", "cpu-host": "interpret"}


def _chunksort_block(hw_name: str | None) -> int:
    """Chunksort tile block for a hardware key, from the live tiling registry
    when repro is importable (keeps this model in lockstep with the kernels);
    falls back to the registry's default block otherwise."""
    try:
        from repro.kernels.capscore.tiling import tile_config
        flavor = _HW_FLAVOR.get(hw_name or DEFAULT_HW, "interpret")
        return tile_config("chunksort", flavor).block[0]
    except Exception:
        return 256


def chunk_step_terms(C=4096, L=8, k=4096, hw=None, block=None) -> dict:
    """Analytic bytes/FLOPs per element for one fused ingest chunk step.

    Models the four device stages of ``update_multi``'s scan body on a chunk
    of C elements across L lanes with per-lane capacity k (table cap k + C,
    pass-1 summary cap k + 1), int32/f32 elements (4 B), worst-case all-keys
    -distinct (U = C — the upper envelope of the output traffic):

      sort       — chunksort: P = next-pow2(C) padded pairs; ONE block-sort
                   pallas_call + log2(P/B) merge calls, each streaming the
                   (key, idx) pairs HBM->VMEM->HBM once (16 B/pair/call);
                   compare-exchange work is 4 ops/pair/stage over the full
                   bitonic + merge-cascade schedule.
      score+agg  — fused capscore_agg: reads (ks, eids, ws) once (12 B/elem),
                   writes 5 aggregate columns x L lanes at U unique keys
                   (20L B/elem worst case); ~32 ops/elem/lane (hash mix,
                   exp-score, min/sum/entry selects).
      merge      — per-lane sorted-runs table merge: table (4 cols) read +
                   written, aggregate columns read; two searchsorted passes
                   (~2 log2(cap) ops/entry).
      pass1      — per-lane key-sorted bottom-(k+1) fold: summary read +
                   written (16 B/entry), chunk mins read; ~log2(k)+2
                   ops/entry merge network.

    Every time bound divides by the SELECTED hardware table — swap ``hw`` to
    move the model across the backend matrix; nothing is chip-hardcoded.
    """
    t = _hw(hw)
    hw_name = hw if isinstance(hw, str) else None
    B = block or _chunksort_block(hw_name)
    P = 1 << max(0, C - 1).bit_length()
    B = min(B, P)
    lgB = int(math.log2(B))
    n_merge = int(math.log2(P // B))
    sort_stages = lgB * (lgB + 1) // 2 + sum(lgB + i for i in range(1, n_merge + 1))
    cap = k + C          # fixed-k lane table capacity
    cap_bk = k + 1       # pass-1 bottom-(k+1) summary capacity

    stages = {
        "sort[chunksort]": {
            "bytes": 16.0 * P * (1 + n_merge),
            "flops": 4.0 * P * sort_stages,
        },
        "score+agg[capscore_agg]": {
            "bytes": C * (12.0 + 20.0 * L),
            "flops": 32.0 * L * C,
        },
        "merge[sorted-runs]": {
            "bytes": L * (32.0 * cap + 20.0 * C),
            "flops": L * 2.0 * (cap + C) * math.log2(cap),
        },
        "pass1[key-sorted fold]": {
            "bytes": L * (16.0 * cap_bk + 8.0 * C),
            "flops": L * (C + cap_bk) * (math.log2(max(k, 2)) + 2.0),
        },
    }
    bound = 0.0
    for s in stages.values():
        s["bytes_per_elem"] = s["bytes"] / C
        s["flops_per_elem"] = s["flops"] / C
        s["intensity"] = s["flops"] / s["bytes"]
        s["t_compute_s"] = s["flops"] / t["peak_flops"]
        s["t_memory_s"] = s["bytes"] / t["hbm_bw"]
        s["dominant"] = ("compute" if s["t_compute_s"] >= s["t_memory_s"]
                         else "memory")
        s["t_bound_s"] = max(s["t_compute_s"], s["t_memory_s"])
        bound += s["t_bound_s"]
    return {
        "chunk": C, "lanes": L, "k": k, "hw": hw_name or "custom",
        "sort_block": B, "sort_pad": P, "stages": stages,
        "step_lower_bound_s": bound,
        "elements_per_s_bound": C / bound if bound else float("inf"),
    }


def print_chunk_step(res: dict) -> None:
    print(f"-- analytic chunk step: C={res['chunk']} L={res['lanes']} "
          f"k={res['k']} on {res['hw']} "
          f"(sort block {res['sort_block']}, pad {res['sort_pad']})")
    print(f"{'stage':26s} {'B/elem':>8s} {'FLOP/elem':>10s} {'FLOP/B':>7s} "
          f"{'t_comp':>9s} {'t_mem':>9s} dominant")
    for name, s in res["stages"].items():
        print(f"{name:26s} {s['bytes_per_elem']:8.1f} "
              f"{s['flops_per_elem']:10.1f} {s['intensity']:7.2f} "
              f"{s['t_compute_s']:9.2e} {s['t_memory_s']:9.2e} {s['dominant']}")
    print(f"step lower bound {res['step_lower_bound_s']:.2e}s -> "
          f"{res['elements_per_s_bound']:,.0f} elements/s")


def load_records(records_dir: str, mesh_tag: str) -> list[dict]:
    out = []
    for fp in sorted(Path(records_dir).glob(f"*__{mesh_tag}.json")):
        out.append(json.loads(fp.read_text()))
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| cell | dominant | compute s | memory s | collective s | "
        "useful/HLO | roofline frac | peak GiB |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['cell']} | {r['dominant']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2%} | "
            f"{r['peak_gib']:.1f} |"
        )
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default="results/dryrun")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--hw", default=DEFAULT_HW, choices=sorted(HW_TABLES),
                    help="hardware table the terms are bounded against")
    ap.add_argument("--chunk-step", action="store_true",
                    help="analytic ingest chunk-step model instead of "
                         "dry-run records")
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--k", type=int, default=4096)
    args = ap.parse_args()

    if args.chunk_step:
        res = chunk_step_terms(C=args.chunk, L=args.lanes, k=args.k,
                               hw=args.hw)
        print_chunk_step(res)
        if args.json_out:
            Path(args.json_out).write_text(json.dumps(res, indent=1))
        return

    recs = load_records(args.records, args.mesh)
    rows = [roofline_terms(r, hw=args.hw) for r in recs]
    rows.sort(key=lambda r: r["roofline_fraction"])
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in rows:
            print(
                f"{r['cell']:44s} {r['dominant']:10s} "
                f"comp {r['t_compute_s']:.2e} mem {r['t_memory_s']:.2e} "
                f"coll {r['t_collective_s']:.2e} useful {r['useful_flops_ratio']:.2f} "
                f"roofline {r['roofline_fraction']:.1%} peak {r['peak_gib']:.1f}GiB"
            )
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
